"""End-to-end behaviour tests for the SD-FEEL system (paper-level claims).

These mirror the qualitative claims validated quantitatively in
EXPERIMENTS.md §Repro; here they run at reduced scale as regression tests.
"""
import numpy as np
import pytest

from repro.core import (
    ClusterSpec, FederationRuntime, MNIST_LATENCY, SDFEELConfig, SyncScheduler,
    ring, fully_connected,
)
from repro.data import FederatedDataset, mnist_like, skewed_label_partition
from repro.models import MnistCNN


@pytest.fixture(scope="module")
def env():
    data = mnist_like(1500, seed=7)
    train, test = data.split(0.8)
    parts = skewed_label_partition(train.y, 12, classes_per_client=2, seed=7)
    ds = FederatedDataset(train, parts)
    eval_batch = {"x": test.x[:256], "y": test.y[:256]}
    return ds, eval_batch


def run_sdfeel(ds, eval_batch, *, tau1=2, tau2=1, alpha=1, topo=ring, iters=40, seed=0):
    spec = ClusterSpec(12, tuple(i // 3 for i in range(12)), ds.data_sizes())
    cfg = SDFEELConfig(clusters=spec, topology=topo(4), tau1=tau1, tau2=tau2,
                       alpha=alpha, learning_rate=0.05)
    sim = FederationRuntime(
        MnistCNN(), SyncScheduler(cfg, latency=MNIST_LATENCY), seed=seed)
    rng = np.random.default_rng(seed)
    return sim.run(iters, lambda k: ds.stacked_batch(8, rng), eval_batch,
                   eval_every=iters)


def test_smaller_tau1_better_per_iteration(env):
    """Remark 1 / Fig. 7a: tau1=1 beats tau1=8 at equal iteration count."""
    ds, eval_batch = env
    h1 = run_sdfeel(ds, eval_batch, tau1=1, iters=40)
    h8 = run_sdfeel(ds, eval_batch, tau1=8, iters=40)
    assert h1.loss[-1] < h8.loss[-1] * 1.1


def test_larger_tau1_cheaper_per_wallclock(env):
    """Remark 1 / Fig. 7b: larger tau1 spends less wall-clock for K iters."""
    ds, eval_batch = env
    h1 = run_sdfeel(ds, eval_batch, tau1=1, iters=30)
    h8 = run_sdfeel(ds, eval_batch, tau1=8, iters=30)
    assert h8.wallclock[-1] < h1.wallclock[-1]


def test_connected_topology_not_worse(env):
    """Remark 2 / Fig. 8: fully-connected >= ring at equal iterations."""
    ds, eval_batch = env
    h_ring = run_sdfeel(ds, eval_batch, tau1=2, tau2=2, iters=40)
    h_full = run_sdfeel(ds, eval_batch, tau1=2, tau2=2, topo=fully_connected, iters=40)
    assert h_full.loss[-1] < h_ring.loss[-1] * 1.15


def test_alpha_closes_ring_gap(env):
    """Remark 2 / Fig. 8: increasing alpha on a ring closes the gap toward
    the fully-connected topology (monotone trend, noise-tolerant)."""
    ds, eval_batch = env
    h_full = run_sdfeel(ds, eval_batch, tau1=2, tau2=2, topo=fully_connected, iters=40)
    h_ring_a1 = run_sdfeel(ds, eval_batch, tau1=2, tau2=2, alpha=1, iters=40)
    h_ring_a8 = run_sdfeel(ds, eval_batch, tau1=2, tau2=2, alpha=8, iters=40)
    gap_a1 = h_ring_a1.loss[-1] - h_full.loss[-1]
    gap_a8 = h_ring_a8.loss[-1] - h_full.loss[-1]
    assert gap_a8 < max(gap_a1, 0.0) + 0.02
