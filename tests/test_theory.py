"""Theorem 1 / 2 numeric-bound tests (Remark 1 & 2 claims)."""
import numpy as np
import pytest

from repro.core import theory
from repro.core.topology import ring, fully_connected, mixing_matrix, zeta


COMMON = dict(eta=1e-3, L=1.0, sigma2=1.0, kappa2=1.0, m=np.full(50, 0.02))


def test_phi_increases_with_tau1_tau2():
    """Remark 1: Phi grows with both aggregation periods."""
    base = theory.theorem1_terms(2, 2, 1, 0.6, **COMMON).Phi
    assert theory.theorem1_terms(4, 2, 1, 0.6, **COMMON).Phi > base
    assert theory.theorem1_terms(2, 4, 1, 0.6, **COMMON).Phi > base


def test_phi_increases_with_zeta_decreases_with_alpha():
    """Remark 2: sparser graphs (larger zeta) hurt; more gossip rounds help."""
    base = theory.theorem1_terms(2, 2, 1, 0.6, **COMMON).Phi
    assert theory.theorem1_terms(2, 2, 1, 0.71, **COMMON).Phi > base
    assert theory.theorem1_terms(2, 2, 4, 0.6, **COMMON).Phi < base
    # diminishing returns in alpha
    d1 = base - theory.theorem1_terms(2, 2, 2, 0.6, **COMMON).Phi
    d2 = (theory.theorem1_terms(2, 2, 4, 0.6, **COMMON).Phi
          - theory.theorem1_terms(2, 2, 8, 0.6, **COMMON).Phi)
    assert d1 > d2 >= 0


def test_hierfavg_limit():
    """Remark 3: zeta^alpha -> 0 recovers the HierFAVG bound (only the
    tau-driven local-drift variance remains)."""
    t_sd = theory.theorem1_terms(2, 2, 64, 0.6, **COMMON)   # zeta^64 ~ 0
    t_perfect = theory.theorem1_terms(2, 2, 1, 0.0, **COMMON)
    assert t_sd.Phi == pytest.approx(t_perfect.Phi, rel=1e-6)


def test_bound_decreases_with_k():
    b1 = theory.theorem1_bound(K=100, delta=1.0, tau1=2, tau2=1, alpha=1, zeta=0.6, **COMMON)
    b2 = theory.theorem1_bound(K=10_000, delta=1.0, tau1=2, tau2=1, alpha=1, zeta=0.6, **COMMON)
    assert b2 < b1


def test_max_learning_rate_shrinks_with_tau():
    lr_small = theory.max_learning_rate(2, 1, 1, 0.6, L=1.0)
    lr_large = theory.max_learning_rate(20, 1, 1, 0.6, L=1.0)
    assert 0 < lr_large < lr_small <= 1.0


def test_delta_max_lemma4():
    # equal speeds: no gap; 2x spread: slowest waits while others finish extra iters
    assert theory.delta_max(np.array([1.0, 1.0, 1.0])) == 0
    dm = theory.delta_max(np.array([1.0, 2.0, 4.0]))
    assert dm == (np.ceil(4 / 1) - 1) + (np.ceil(4 / 2) - 1)


def test_theorem2_lr_condition():
    assert theory.theorem2_learning_rate_ok(1e-4, L=1.0, theta_min=1, theta_max=8, dmax=4)
    assert not theory.theorem2_learning_rate_ok(0.5, L=1.0, theta_min=1, theta_max=8, dmax=4)


def test_zeta_matches_fig3_values():
    assert zeta(mixing_matrix(ring(6))) == pytest.approx(0.6, abs=0.02)
    assert zeta(mixing_matrix(fully_connected(6))) == pytest.approx(0.0, abs=1e-8)
