"""Topology / mixing-matrix (eq. 5) unit tests."""
import numpy as np
import pytest

from repro.core import topology as T


def test_constructors_connected():
    for topo in [T.ring(6), T.star(6), T.fully_connected(6), T.chain(5),
                 T.partially_connected(6), T.torus_2d(3, 4)]:
        assert topo.is_connected()
        a = topo.adjacency
        assert np.array_equal(a, a.T)
        assert np.all(np.diag(a) == 0)


def test_ring_degrees():
    topo = T.ring(6)
    assert np.all(topo.degree() == 2)
    assert list(topo.neighbors(0)) == [1, 5]


def test_mixing_matrix_mass_and_fixed_point():
    """1^T P = 1^T (mass preservation) and P m~ = m~ (weighted-mean fixed pt)."""
    rng = np.random.default_rng(0)
    for make in (T.ring, T.star, T.fully_connected):
        topo = make(6)
        m = rng.uniform(0.5, 2.0, 6)
        m = m / m.sum()
        p = T.mixing_matrix(topo, m)
        np.testing.assert_allclose(p.sum(axis=0), 1.0, atol=1e-10)
        np.testing.assert_allclose(p @ m, m, atol=1e-10)


def test_zeta_orderings_match_fig3():
    """Fig. 3: star (0.71) > ring (0.6) > partial > fully-connected (0)."""
    z = {name: T.zeta(T.mixing_matrix(make(6))) for name, make in
         [("star", T.star), ("ring", T.ring), ("full", T.fully_connected)]}
    assert z["star"] == pytest.approx(0.714, abs=0.02)
    assert z["ring"] == pytest.approx(0.6, abs=0.02)
    assert z["full"] == pytest.approx(0.0, abs=1e-8)
    zp = T.zeta(T.mixing_matrix(T.partially_connected(6, extra_edges=3, seed=1)))
    assert z["full"] < zp < z["star"]


def test_gossip_converges_to_weighted_mean():
    """P^alpha Y -> weighted mean as alpha grows; rate ~ zeta^alpha."""
    rng = np.random.default_rng(1)
    topo = T.ring(8)
    m = rng.uniform(0.5, 1.5, 8)
    m = m / m.sum()
    p = T.mixing_matrix(topo, m)
    y = rng.normal(size=(8, 5))
    target = (m @ y)[None, :].repeat(8, axis=0)
    prev_err = np.inf
    for alpha in (1, 4, 16, 64):
        ya = np.linalg.matrix_power(p.T, alpha) @ y
        err = np.abs(ya - target).max()
        assert err < prev_err or err < 1e-10
        prev_err = err
    assert prev_err < 1e-6


def test_disconnected_raises():
    a = np.zeros((4, 4), dtype=np.int64)
    a[0, 1] = a[1, 0] = 1
    a[2, 3] = a[3, 2] = 1
    with pytest.raises(ValueError):
        T.Topology("two_islands", 4, a)


def test_from_edges_validation():
    with pytest.raises(ValueError, match="out of range"):
        T.from_edges(3, [(0, 1), (1, 3), (0, 2)])
    with pytest.raises(ValueError, match="self-loop"):
        T.from_edges(3, [(0, 1), (1, 1), (1, 2)])
    with pytest.raises(ValueError, match="duplicate"):
        T.from_edges(3, [(0, 1), (1, 2), (2, 1), (0, 2)])
    topo = T.from_edges(4, [(0, 1), (1, 2), (2, 3), (3, 0)])
    assert np.array_equal(topo.adjacency, T.ring(4).adjacency)


def test_connected_components():
    # a connected Topology has exactly one component covering every server
    comps = T.ring(5).connected_components()
    assert len(comps) == 1
    np.testing.assert_array_equal(comps[0], np.arange(5))
    # the module-level function handles the disconnected adjacencies the
    # fault-degradation path produces (which Topology itself rejects)
    a = np.zeros((6, 6), dtype=np.int64)
    a[0, 1] = a[1, 0] = 1           # {0, 1}
    a[2, 3] = a[3, 2] = 1           # {2, 3, 4} via 3-4
    a[3, 4] = a[4, 3] = 1
    comps = T.connected_components(a)  # server 5 is a singleton
    assert [c.tolist() for c in comps] == [[0, 1], [2, 3, 4], [5]]
